"""Paper Fig 8: hot-store budget sensitivity.

Sweep the hot-store size; reloads drop to ~0 beyond a threshold and
runtime stabilizes — the paper's 'once the hot store is large enough to
avoid evictions, performance stabilizes'.
"""

from __future__ import annotations

import tempfile

from benchmarks.common import bench_graph, gnn_specs, run_atlas, save
from repro.core.atlas import AtlasConfig
from repro.core.reorder import make_order, relabel_features_chunked, relabel_graph


def run(v=20_000, deg=12, d=64, fracs=(40, 20, 10, 5, 3, 2, 1)):
    csr, feats = bench_graph(v=v, deg=deg, d=d)
    order = make_order("at", csr)
    csr_r = relabel_graph(csr, order)
    feats_r = relabel_features_chunked(feats, order)
    specs = gnn_specs("gcn", d)
    rows = []
    for frac in fracs:
        slots = max(64, v // frac)
        cfg = AtlasConfig(chunk_bytes=512 * d * 4, hot_slots=slots, eviction="at")
        with tempfile.TemporaryDirectory() as td:
            _, metrics, wall = run_atlas(td, csr_r, feats_r, specs, cfg)
        m0 = metrics[0]
        rows.append({
            "hot_slots": slots, "wall_s": wall, "reloads": m0.reloads,
            "evictions": m0.evictions,
            "peak_cold": m0.peak_cold_resident,
        })
        print(f"[fig8] slots={slots:7d}: reloads={m0.reloads:7d} "
              f"peak_cold={m0.peak_cold_resident:7d} wall={wall:.1f}s")
    save("fig8_hotstore", rows)
    assert rows[-1]["reloads"] == 0, "largest budget must eliminate reloads"
    return rows


if __name__ == "__main__":
    run()
