"""Paper Fig 8: hot-store budget sensitivity.

Sweep the hot-store size; reloads drop to ~0 beyond a threshold and
runtime stabilizes — the paper's 'once the hot store is large enough to
avoid evictions, performance stabilizes'.

Ordering happens at store build (``GraphStore.create(order="at")``);
one store per budget point keeps runs independent.  Features go through
an on-disk memmap above ``--mmap-threshold`` vertices so the sweep runs
at V>=1M.
"""

from __future__ import annotations

import argparse
import os
import tempfile

from benchmarks.common import GRAPH_BUILDERS, gnn_specs, run_atlas, save
from repro.core.atlas import AtlasConfig
from repro.graphs.synth import make_features, make_features_mmap


def run(v=20_000, deg=12, d=64, fracs=(40, 20, 10, 5, 3, 2, 1),
        mmap_threshold=200_000, graph="powerlaw"):
    csr = GRAPH_BUILDERS[graph](v, deg, seed=7)
    specs = gnn_specs("gcn", d)
    rows = []
    with tempfile.TemporaryDirectory() as scratch:
        if v >= mmap_threshold:
            feats = make_features_mmap(v, d, os.path.join(scratch, "feats.npy"),
                                       seed=8)
        else:
            feats = make_features(v, d, seed=8)
        for frac in fracs:
            slots = max(64, v // frac)
            cfg = AtlasConfig(chunk_bytes=512 * d * 4, hot_slots=slots,
                              eviction="at")
            with tempfile.TemporaryDirectory() as td:
                _, metrics, wall = run_atlas(td, csr, feats, specs, cfg,
                                             order="at")
            m0 = metrics[0]
            rows.append({
                "graph": graph,
                "hot_slots": slots, "wall_s": wall, "reloads": m0.reloads,
                "evictions": m0.evictions,
                "peak_cold": m0.peak_cold_resident,
            })
            print(f"[fig8] slots={slots:7d}: reloads={m0.reloads:7d} "
                  f"peak_cold={m0.peak_cold_resident:7d} wall={wall:.1f}s")
    save("fig8_hotstore", rows)
    assert rows[-1]["reloads"] == 0, "largest budget must eliminate reloads"
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=20_000)
    ap.add_argument("--degree", type=int, default=12)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--fracs", nargs="+", type=int,
                    default=[40, 20, 10, 5, 3, 2, 1])
    ap.add_argument("--mmap-threshold", type=int, default=200_000)
    ap.add_argument("--graph", default="powerlaw",
                    choices=sorted(GRAPH_BUILDERS))
    args = ap.parse_args()
    run(v=args.vertices, deg=args.degree, d=args.dim,
        fracs=tuple(args.fracs), mmap_threshold=args.mmap_threshold,
        graph=args.graph)


if __name__ == "__main__":
    main()
