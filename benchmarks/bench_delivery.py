"""Delivery-core microbenchmark: array vs python eviction bookkeeping.

Measures the engine's innermost loop — ``AtlasEngine._deliver`` routing
pre-aggregated per-chunk records through the memory manager, eviction
policy, and orchestrator — with everything else (disk, feature I/O,
dense transforms) stubbed out, so the number isolates the bookkeeping
cost the array-native refactor targets.  ``--mode engine`` additionally
times a full ``run_layer`` on a real on-disk store for an end-to-end
view.

Usage:
    PYTHONPATH=src python benchmarks/bench_delivery.py
    PYTHONPATH=src python benchmarks/bench_delivery.py --vertices 250000 \
        --policies at,lru --mode both

Acceptance target (ISSUE 1): >= 3x delivery throughput for
``policy_impl='array'`` over ``'python'`` at >= 100k vertices.
"""

from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np

from repro.core import orchestrator as ost
from repro.core.atlas import AtlasConfig, AtlasEngine
from repro.core.eviction import make_policy
from repro.core.memory_manager import MemoryManager
from repro.core.orchestrator import Orchestrator
from repro.graphs.csr import degrees_from_csr
from repro.graphs.synth import make_features, powerlaw_graph
from repro.models.gnn import init_gnn_params
from repro.storage.layout import GraphStore


class RamColdStore:
    """In-memory cold tier so the microbench times bookkeeping, not disk."""

    def __init__(self, num_vertices: int, dim: int, dtype=np.float32):
        self._rows = np.zeros((num_vertices, dim), dtype=dtype)
        self.peak_resident = 0
        self._resident = 0

    def put(self, vertex_ids, rows):
        self._rows[vertex_ids] = rows
        self._resident += len(vertex_ids)
        self.peak_resident = max(self.peak_resident, self._resident)

    def take(self, vertex_ids):
        self._resident -= len(vertex_ids)
        return self._rows[vertex_ids].copy()


class SinkGrad:
    """Graduation stub: count rows, drop them."""

    def __init__(self):
        self.graduated = 0

    def add(self, vertex_ids, rows):
        self.graduated += len(vertex_ids)


def build_chunks(csr, chunk_vertices: int):
    """Per-chunk (unique destinations, message counts) from the topology."""
    chunks = []
    for start in range(0, csr.num_vertices, chunk_vertices):
        end = min(start + chunk_vertices, csr.num_vertices)
        _, dst = csr.edges_for_range(start, end)
        u_dst, counts = np.unique(np.asarray(dst, dtype=np.int64), return_counts=True)
        chunks.append((u_dst, counts.astype(np.int64)))
    return chunks


def run_micro(csr, chunks, impl: str, hot_slots: int, dim: int, seed: int):
    in_deg, _ = degrees_from_csr(csr)
    required = in_deg.astype(np.int64)
    num_vertices = csr.num_vertices
    orch = Orchestrator(required)
    policy = make_policy(
        "at", seed=seed, impl=impl,
        num_vertices=num_vertices, max_pending=int(required.max()),
    )
    cold = RamColdStore(num_vertices, dim)
    mm = MemoryManager(
        num_slots=hot_slots, dim=dim, dtype=np.float32,
        orchestrator=orch, policy=policy, cold=cold,
    )
    grad = SinkGrad()
    shield = np.zeros(num_vertices, dtype=bool)
    delivered = 0
    reloads = 0
    t0 = time.perf_counter()
    for index, (u_dst, counts) in enumerate(chunks):
        shield[u_dst] = True
        partial = np.ones((len(u_dst), dim), dtype=np.float32)
        reloads += AtlasEngine._deliver(
            mm, orch, grad, u_dst, partial, counts,
            col_offset=0, shield=shield, chunk_index=index,
        )
        delivered += len(u_dst)
        shield[u_dst] = False
    seconds = time.perf_counter() - t0
    assert grad.graduated == int(np.sum(required > 0))
    return {
        "impl": impl,
        "seconds": seconds,
        "chunks": len(chunks),
        "chunks_per_s": len(chunks) / seconds,
        "delivered_vertices": delivered,
        "vertices_per_s": delivered / seconds,
        "evictions": mm.eviction_count,
        "reloads": mm.reload_count,
    }


def run_engine(
    csr,
    feats,
    impl: str,
    hot_slots: int,
    chunk_vertices: int,
    seed: int,
    backend: str = "numpy",
):
    d = feats.shape[1]
    specs = init_gnn_params("gcn", [d, 8], seed=seed)
    cfg = AtlasConfig(
        chunk_bytes=chunk_vertices * d * 4,
        hot_slots=hot_slots,
        eviction="at",
        policy_impl=impl,
        backend=backend,
        seed=seed,
    )
    with tempfile.TemporaryDirectory() as td:
        store = GraphStore.create(td + "/store", csr, feats, num_partitions=4)
        t0 = time.perf_counter()
        _, metrics = AtlasEngine(cfg).run(store, specs, td + "/work")
        seconds = time.perf_counter() - t0
    m = metrics[0]
    return {
        "impl": impl,
        "backend": backend,
        "seconds": seconds,
        "chunks": m.chunks,
        "chunks_per_s": m.chunks / seconds,
        "vertices_per_s": csr.num_vertices / seconds,
        "evictions": m.evictions,
        "reloads": m.reloads,
    }


def report(title: str, results: dict) -> float:
    py, ar = results["python"], results["array"]
    assert py["evictions"] == ar["evictions"], "impls diverged (evictions)"
    assert py["reloads"] == ar["reloads"], "impls diverged (reloads)"
    speedup = py["seconds"] / ar["seconds"]
    print(f"\n== {title} ==")
    for r in (py, ar):
        print(
            f"  {r['impl']:<7} {r['seconds']:8.3f}s   "
            f"{r['chunks_per_s']:10.1f} chunks/s   "
            f"{r['vertices_per_s']:12.0f} vertices/s   "
            f"evictions={r['evictions']} reloads={r['reloads']}"
        )
    print(f"  speedup (array over python): {speedup:.2f}x")
    return speedup


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--vertices", type=int, default=120_000)
    ap.add_argument("--avg-degree", type=int, default=8)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--hot-frac", type=float, default=0.125,
                    help="hot slots as a fraction of vertices")
    ap.add_argument("--chunk-vertices", type=int, default=4096)
    ap.add_argument("--mode", choices=["micro", "engine", "both", "backend"],
                    default="micro")
    ap.add_argument("--backend", default="numpy", choices=["numpy", "jax"],
                    help="chunk-aggregation backend for --mode engine runs")
    ap.add_argument("--repeats", type=int, default=3,
                    help="repetitions per impl; best (min-time) run is reported")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true", help="emit raw results as JSON")
    args = ap.parse_args()

    hot_slots = max(16, int(args.vertices * args.hot_frac))
    print(
        f"graph: V={args.vertices} avg_deg={args.avg_degree} d={args.dim} "
        f"hot_slots={hot_slots} chunk_vertices={args.chunk_vertices}"
    )
    csr = powerlaw_graph(args.vertices, args.avg_degree, seed=args.seed,
                         self_loops=True)
    all_results = {}
    best = lambda runs: min(runs, key=lambda r: r["seconds"])
    reps = max(1, args.repeats)
    if args.mode in ("micro", "both"):
        chunks = build_chunks(csr, args.chunk_vertices)
        res = {
            impl: best([
                run_micro(csr, chunks, impl, hot_slots, args.dim, args.seed)
                for _ in range(reps)
            ])
            for impl in ("python", "array")
        }
        all_results["micro"] = {**res, "speedup": report("micro (_deliver only)", res)}
    if args.mode in ("engine", "both"):
        feats = make_features(args.vertices, args.dim, seed=args.seed)
        res = {
            impl: best([
                run_engine(csr, feats, impl, hot_slots, args.chunk_vertices,
                           args.seed, backend=args.backend)
                for _ in range(reps)
            ])
            for impl in ("python", "array")
        }
        all_results["engine"] = {**res, "speedup": report("engine (full run_layer)", res)}
    if args.mode == "backend":
        # ROADMAP item: numpy vs jax chunk aggregation end-to-end, with the
        # array policy impl fixed so only the aggregation backend varies
        feats = make_features(args.vertices, args.dim, seed=args.seed)
        res = {
            backend: best([
                run_engine(csr, feats, "array", hot_slots, args.chunk_vertices,
                           args.seed, backend=backend)
                for _ in range(reps)
            ])
            for backend in ("numpy", "jax")
        }
        ny, jx = res["numpy"], res["jax"]
        assert ny["evictions"] == jx["evictions"], "backends diverged (evictions)"
        speedup = ny["seconds"] / jx["seconds"]
        print("\n== backend (full run_layer, policy_impl=array) ==")
        for r in (ny, jx):
            print(
                f"  {r['backend']:<7} {r['seconds']:8.3f}s   "
                f"{r['chunks_per_s']:10.1f} chunks/s   "
                f"{r['vertices_per_s']:12.0f} vertices/s   "
                f"evictions={r['evictions']} reloads={r['reloads']}"
            )
        print(f"  speedup (jax over numpy): {speedup:.2f}x")
        all_results["backend"] = {**res, "jax_speedup": speedup}
    if args.json:
        print(json.dumps(all_results, indent=2))


if __name__ == "__main__":
    main()
