"""Delivery-core microbenchmark: array vs python eviction bookkeeping.

Measures the engine's innermost loop — ``AtlasEngine._deliver`` routing
pre-aggregated per-chunk records through the memory manager, eviction
policy, and orchestrator — with everything else (disk, feature I/O,
dense transforms) stubbed out, so the number isolates the bookkeeping
cost the array-native refactor targets.  ``--mode engine`` additionally
times a full ``run_layer`` on a real on-disk store for an end-to-end
view.

Usage:
    PYTHONPATH=src python benchmarks/bench_delivery.py
    PYTHONPATH=src python benchmarks/bench_delivery.py --vertices 250000 \
        --policies at,lru --mode both

Acceptance target (ISSUE 1): >= 3x delivery throughput for
``policy_impl='array'`` over ``'python'`` at >= 100k vertices.
"""

from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np

from repro.core import orchestrator as ost
from repro.core.atlas import AtlasConfig, AtlasEngine, spills_to_dense
from repro.core.eviction import make_policy
from repro.core.memory_manager import MemoryManager
from repro.core.orchestrator import Orchestrator
from repro.graphs.csr import degrees_from_csr
from repro.graphs.synth import make_features, powerlaw_graph
from repro.models.gnn import init_gnn_params
from repro.session import AtlasSession
from repro.storage.layout import GraphStore


class RamColdStore:
    """In-memory cold tier so the microbench times bookkeeping, not disk."""

    def __init__(self, num_vertices: int, dim: int, dtype=np.float32):
        self._rows = np.zeros((num_vertices, dim), dtype=dtype)
        self.peak_resident = 0
        self._resident = 0

    def put(self, vertex_ids, rows):
        self._rows[vertex_ids] = rows
        self._resident += len(vertex_ids)
        self.peak_resident = max(self.peak_resident, self._resident)

    def take(self, vertex_ids):
        self._resident -= len(vertex_ids)
        return self._rows[vertex_ids].copy()


class SinkGrad:
    """Graduation stub: count rows, drop them."""

    def __init__(self):
        self.graduated = 0

    def add(self, vertex_ids, rows):
        self.graduated += len(vertex_ids)

    def add_gather(self, vertex_ids, source, rows_index):
        self.graduated += len(vertex_ids)


def build_chunks(csr, chunk_vertices: int):
    """Per-chunk (unique destinations, message counts) from the topology."""
    chunks = []
    for start in range(0, csr.num_vertices, chunk_vertices):
        end = min(start + chunk_vertices, csr.num_vertices)
        _, dst = csr.edges_for_range(start, end)
        u_dst, counts = np.unique(np.asarray(dst, dtype=np.int64), return_counts=True)
        chunks.append((u_dst, counts.astype(np.int64)))
    return chunks


def run_micro(csr, chunks, impl: str, hot_slots: int, dim: int, seed: int):
    in_deg, _ = degrees_from_csr(csr)
    required = in_deg.astype(np.int64)
    num_vertices = csr.num_vertices
    orch = Orchestrator(required)
    policy = make_policy(
        "at", seed=seed, impl=impl,
        num_vertices=num_vertices, max_pending=int(required.max()),
    )
    cold = RamColdStore(num_vertices, dim)
    mm = MemoryManager(
        num_slots=hot_slots, dim=dim, dtype=np.float32,
        orchestrator=orch, policy=policy, cold=cold,
    )
    grad = SinkGrad()
    shield = np.zeros(num_vertices, dtype=bool)
    delivered = 0
    reloads = 0
    t0 = time.perf_counter()
    for index, (u_dst, counts) in enumerate(chunks):
        shield[u_dst] = True
        partial = np.ones((len(u_dst), dim), dtype=np.float32)
        reloads += AtlasEngine._deliver(
            mm, orch, grad, u_dst, partial, counts,
            col_offset=0, shield=shield, chunk_index=index,
        )
        delivered += len(u_dst)
        shield[u_dst] = False
    seconds = time.perf_counter() - t0
    assert grad.graduated == int(np.sum(required > 0))
    return {
        "impl": impl,
        "seconds": seconds,
        "chunks": len(chunks),
        "chunks_per_s": len(chunks) / seconds,
        "delivered_vertices": delivered,
        "vertices_per_s": delivered / seconds,
        "evictions": mm.eviction_count,
        "reloads": mm.reload_count,
    }


def run_engine(
    csr,
    feats,
    impl: str,
    hot_slots: int,
    chunk_vertices: int,
    seed: int,
    backend: str = "numpy",
):
    """Full run_layer on a real on-disk store.  ``impl`` selects BOTH the
    eviction-policy impl and the layer-tail impl (python = full scalar
    oracle baseline, array = the vectorized engine)."""
    d = feats.shape[1]
    specs = init_gnn_params("gcn", [d, 8], seed=seed)
    cfg = AtlasConfig(
        chunk_bytes=chunk_vertices * d * 4,
        hot_slots=hot_slots,
        eviction="at",
        policy_impl=impl,
        tail_impl=impl,
        backend=backend,
        seed=seed,
    )
    with tempfile.TemporaryDirectory() as td:
        store = GraphStore.create(td + "/store", csr, feats, num_partitions=4)
        session = AtlasSession(store, config=cfg, workdir=td + "/work")
        t0 = time.perf_counter()
        result = session.infer(specs)
        seconds = time.perf_counter() - t0
        spills, metrics = result.final.spills, result.metrics
        out = spills_to_dense(spills, csr.num_vertices, specs[-1].out_dim)
    m = metrics[0]
    return {
        "impl": impl,
        "backend": backend,
        "seconds": seconds,
        "chunks": m.chunks,
        "chunks_per_s": m.chunks / seconds,
        "vertices_per_s": csr.num_vertices / seconds,
        "evictions": m.evictions,
        "reloads": m.reloads,
        "tail_seconds": m.tail_seconds,
        "tail_rows_per_s": m.tail_rows_per_s,
        "transform_seconds": m.transform_seconds,
        "spill_seconds": m.spill_seconds,
        "output": out,
    }


def capture_graduation_stream(csr, feats, hot_slots, chunk_vertices, seed):
    """One engine run with ``GraduationProcessor.add_gather`` shimmed to
    record the exact per-call id batches the delivery loop produces — the
    real layer-tail workload, replayed below under both tail impls."""
    from repro.core.graduation import GraduationProcessor

    batches: list[np.ndarray] = []
    orig = GraduationProcessor.add_gather

    def recording(self, ids, source, rows_index):
        batches.append(np.asarray(ids).copy())
        return orig(self, ids, source, rows_index)

    GraduationProcessor.add_gather = recording
    try:
        run_engine(csr, feats, "array", hot_slots, chunk_vertices, seed)
    finally:
        GraduationProcessor.add_gather = orig
    return batches


def run_tail_replay(batches, num_vertices: int, dim: int, hot_slots: int, seed: int):
    """Replay the captured graduation stream through both tail impls,
    single-threaded (no GIL cross-talk), and isolate the bookkeeping cost:
    total minus the dense transform and the physical spill write, which
    are identical work under either impl.  Asserts bit-identical output."""
    from repro.core.graduation import make_graduation
    from repro.storage.writer import EmbeddingWriter

    rng = np.random.default_rng(seed)
    hot = rng.standard_normal((hot_slots, dim)).astype(np.float32)
    slot_batches = [
        rng.integers(0, hot_slots, len(b)).astype(np.int64) for b in batches
    ]
    spec = init_gnn_params("gcn", [dim, 8], seed=seed)[0]
    from repro.models.gnn import layer_update

    results, outputs = {}, {}
    for impl in ("python", "array"):
        best = None
        for _ in range(3):
            with tempfile.TemporaryDirectory() as td:
                w = EmbeddingWriter(
                    td, num_vertices=num_vertices, dim=8, dtype=np.float32,
                    num_partitions=8, buffer_rows=4096,
                    threaded=False, ingest_impl=impl,
                )
                g = make_graduation(
                    impl, transform=lambda r: layer_update(spec, r),
                    sink=w.write, dim=dim, dtype=np.float32,
                    buffer_rows=8192, threaded=False,
                )
                t0 = time.perf_counter()
                for ids, slots in zip(batches, slot_batches):
                    g.add_gather(ids, hot, slots)
                g.close()
                spills = w.close()
                total = time.perf_counter() - t0
                book = total - g.transform_seconds - w.spill_seconds
                if best is None or book < best["tail_seconds"]:
                    best = {
                        "impl": impl,
                        "tail_seconds": book,
                        "tail_rows_per_s": num_vertices / book,
                        "total_seconds": total,
                        "transform_seconds": g.transform_seconds,
                        "spill_seconds": w.spill_seconds,
                    }
                if impl not in outputs:
                    outputs[impl] = spills_to_dense(spills, num_vertices, 8)
        results[impl] = best
    assert np.array_equal(outputs["python"], outputs["array"]), (
        "tail impls diverged (spill contents)"
    )
    return results


def report(title: str, results: dict) -> float:
    py, ar = results["python"], results["array"]
    assert py["evictions"] == ar["evictions"], "impls diverged (evictions)"
    assert py["reloads"] == ar["reloads"], "impls diverged (reloads)"
    speedup = py["seconds"] / ar["seconds"]
    print(f"\n== {title} ==")
    for r in (py, ar):
        print(
            f"  {r['impl']:<7} {r['seconds']:8.3f}s   "
            f"{r['chunks_per_s']:10.1f} chunks/s   "
            f"{r['vertices_per_s']:12.0f} vertices/s   "
            f"evictions={r['evictions']} reloads={r['reloads']}"
        )
    print(f"  speedup (array over python): {speedup:.2f}x")
    return speedup


def report_tail(results: dict) -> float:
    """Layer-tail (graduation bookkeeping + writer scatter) throughput
    from the single-threaded stream replay, excluding the dense transform
    and physical spill write that are identical work under either impl."""
    py, ar = results["python"], results["array"]
    tail_speedup = ar["tail_rows_per_s"] / py["tail_rows_per_s"]
    print("  -- layer tail (graduation + spill scatter), stream replay --")
    for r in (py, ar):
        print(
            f"  {r['impl']:<7} {r['tail_seconds']*1000:8.1f}ms tail   "
            f"{r['tail_rows_per_s']:12.0f} rows/s   "
            f"(transform {r['transform_seconds']:.3f}s, "
            f"spill {r['spill_seconds']:.3f}s)"
        )
    print(f"  tail speedup (array over python): {tail_speedup:.2f}x")
    return tail_speedup


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--vertices", type=int, default=120_000)
    ap.add_argument("--avg-degree", type=int, default=8)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--hot-frac", type=float, default=0.125,
                    help="hot slots as a fraction of vertices")
    ap.add_argument("--chunk-vertices", type=int, default=4096)
    ap.add_argument("--mode", choices=["micro", "engine", "both", "backend"],
                    default="micro")
    ap.add_argument("--backend", default="numpy", choices=["numpy", "jax"],
                    help="chunk-aggregation backend for --mode engine runs")
    ap.add_argument("--repeats", type=int, default=3,
                    help="repetitions per impl; best (min-time) run is reported")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write raw results as JSON to PATH ('-' for stdout)")
    args = ap.parse_args()

    hot_slots = max(16, int(args.vertices * args.hot_frac))
    print(
        f"graph: V={args.vertices} avg_deg={args.avg_degree} d={args.dim} "
        f"hot_slots={hot_slots} chunk_vertices={args.chunk_vertices}"
    )
    csr = powerlaw_graph(args.vertices, args.avg_degree, seed=args.seed,
                         self_loops=True)
    all_results = {}
    best = lambda runs: min(runs, key=lambda r: r["seconds"])
    reps = max(1, args.repeats)
    if args.mode in ("micro", "both"):
        chunks = build_chunks(csr, args.chunk_vertices)
        res = {
            impl: best([
                run_micro(csr, chunks, impl, hot_slots, args.dim, args.seed)
                for _ in range(reps)
            ])
            for impl in ("python", "array")
        }
        all_results["micro"] = {**res, "speedup": report("micro (_deliver only)", res)}
    if args.mode in ("engine", "both"):
        feats = make_features(args.vertices, args.dim, seed=args.seed)
        res = {
            impl: best([
                run_engine(csr, feats, impl, hot_slots, args.chunk_vertices,
                           args.seed, backend=args.backend)
                for _ in range(reps)
            ])
            for impl in ("python", "array")
        }
        # the array tail must reproduce the python-oracle spills bit for bit
        out_py, out_ar = res["python"].pop("output"), res["array"].pop("output")
        if not np.array_equal(out_py, out_ar):
            raise AssertionError("impls diverged (spill contents)")
        speedup = report("engine (full run_layer)", res)
        print("  spill contents: bit-identical across impls")
        # layer-tail throughput: replay the engine's real graduation
        # stream through both tail impls, single-threaded and isolated
        batches = capture_graduation_stream(
            csr, feats, hot_slots, args.chunk_vertices, args.seed
        )
        tail = run_tail_replay(
            batches, args.vertices, args.dim, hot_slots, args.seed
        )
        tail_speedup = report_tail(tail)
        print("  tail replay spill contents: bit-identical across impls")
        all_results["engine"] = {
            **res, "speedup": speedup,
            "tail": tail, "tail_speedup": tail_speedup,
        }
    if args.mode == "backend":
        # ROADMAP item: numpy vs jax chunk aggregation end-to-end, with the
        # array policy impl fixed so only the aggregation backend varies
        feats = make_features(args.vertices, args.dim, seed=args.seed)
        res = {
            backend: best([
                run_engine(csr, feats, "array", hot_slots, args.chunk_vertices,
                           args.seed, backend=backend)
                for _ in range(reps)
            ])
            for backend in ("numpy", "jax")
        }
        ny, jx = res["numpy"], res["jax"]
        # backends differ in float op order: same bookkeeping, not bitwise
        ny.pop("output"), jx.pop("output")
        assert ny["evictions"] == jx["evictions"], "backends diverged (evictions)"
        speedup = ny["seconds"] / jx["seconds"]
        print("\n== backend (full run_layer, policy_impl=array) ==")
        for r in (ny, jx):
            print(
                f"  {r['backend']:<7} {r['seconds']:8.3f}s   "
                f"{r['chunks_per_s']:10.1f} chunks/s   "
                f"{r['vertices_per_s']:12.0f} vertices/s   "
                f"evictions={r['evictions']} reloads={r['reloads']}"
            )
        print(f"  speedup (jax over numpy): {speedup:.2f}x")
        all_results["backend"] = {**res, "jax_speedup": speedup}
    if args.json == "-":
        print(json.dumps(all_results, indent=2))
    elif args.json:
        with open(args.json, "w") as f:
            json.dump(all_results, f, indent=2)
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()
