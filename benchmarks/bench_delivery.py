"""Delivery-core microbenchmark: array vs python eviction bookkeeping.

Measures the engine's innermost loop — ``AtlasEngine._deliver`` routing
pre-aggregated per-chunk records through the memory manager, eviction
policy, and orchestrator — with everything else (disk, feature I/O,
dense transforms) stubbed out, so the number isolates the bookkeeping
cost the array-native refactor targets.  ``--mode engine`` additionally
times a full ``run_layer`` on a real on-disk store for an end-to-end
view; ``--mode io`` compares the spill-durability impls (synchronous
fsync-per-spill vs the write-back scheduler's group commit) across a
hot-store-fraction sweep, asserting bit-identical dense spills.

``--mmap-features`` generates the synthetic feature matrix straight
into an on-disk ``.npy`` and feeds the store from a read-only memmap,
so multi-M-vertex graphs (ROADMAP item) never materialise V×d floats
in RAM; it turns itself on automatically at --vertices >= 1M.

Usage:
    PYTHONPATH=src python benchmarks/bench_delivery.py
    PYTHONPATH=src python benchmarks/bench_delivery.py --vertices 250000 \
        --policies at,lru --mode both
    PYTHONPATH=src python benchmarks/bench_delivery.py --mode io \
        --vertices 2000000 --mmap-features --hot-fracs 0.05,0.125,0.25

Acceptance targets: >= 3x delivery throughput for
``policy_impl='array'`` over ``'python'`` at >= 100k vertices (ISSUE 1);
``io_impl='writeback'`` cuts layer-critical-path spill seconds vs
``'sync'`` with barrier time reported separately (ISSUE 5).
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from repro.core import orchestrator as ost
from repro.core.atlas import AtlasConfig, AtlasEngine, spills_to_dense
from repro.core.eviction import make_policy
from repro.core.memory_manager import MemoryManager
from repro.core.orchestrator import Orchestrator
from repro.graphs.csr import degrees_from_csr
from repro.graphs.synth import make_features, make_features_mmap, powerlaw_graph
from repro.models.gnn import init_gnn_params
from repro.session import AtlasSession
from repro.storage.layout import GraphStore


class RamColdStore:
    """In-memory cold tier so the microbench times bookkeeping, not disk."""

    def __init__(self, num_vertices: int, dim: int, dtype=np.float32):
        self._rows = np.zeros((num_vertices, dim), dtype=dtype)
        self.peak_resident = 0
        self._resident = 0

    def put(self, vertex_ids, rows):
        self._rows[vertex_ids] = rows
        self._resident += len(vertex_ids)
        self.peak_resident = max(self.peak_resident, self._resident)

    def take(self, vertex_ids):
        self._resident -= len(vertex_ids)
        return self._rows[vertex_ids].copy()


class SinkGrad:
    """Graduation stub: count rows, drop them."""

    def __init__(self):
        self.graduated = 0

    def add(self, vertex_ids, rows):
        self.graduated += len(vertex_ids)

    def add_gather(self, vertex_ids, source, rows_index):
        self.graduated += len(vertex_ids)


def build_chunks(csr, chunk_vertices: int):
    """Per-chunk (unique destinations, message counts) from the topology."""
    chunks = []
    for start in range(0, csr.num_vertices, chunk_vertices):
        end = min(start + chunk_vertices, csr.num_vertices)
        _, dst = csr.edges_for_range(start, end)
        u_dst, counts = np.unique(np.asarray(dst, dtype=np.int64), return_counts=True)
        chunks.append((u_dst, counts.astype(np.int64)))
    return chunks


def run_micro(csr, chunks, impl: str, hot_slots: int, dim: int, seed: int):
    in_deg, _ = degrees_from_csr(csr)
    required = in_deg.astype(np.int64)
    num_vertices = csr.num_vertices
    orch = Orchestrator(required)
    policy = make_policy(
        "at", seed=seed, impl=impl,
        num_vertices=num_vertices, max_pending=int(required.max()),
    )
    cold = RamColdStore(num_vertices, dim)
    mm = MemoryManager(
        num_slots=hot_slots, dim=dim, dtype=np.float32,
        orchestrator=orch, policy=policy, cold=cold,
    )
    grad = SinkGrad()
    shield = np.zeros(num_vertices, dtype=bool)
    delivered = 0
    reloads = 0
    t0 = time.perf_counter()
    for index, (u_dst, counts) in enumerate(chunks):
        shield[u_dst] = True
        partial = np.ones((len(u_dst), dim), dtype=np.float32)
        reloads += AtlasEngine._deliver(
            mm, orch, grad, u_dst, partial, counts,
            col_offset=0, shield=shield, chunk_index=index,
        )
        delivered += len(u_dst)
        shield[u_dst] = False
    seconds = time.perf_counter() - t0
    assert grad.graduated == int(np.sum(required > 0))
    return {
        "impl": impl,
        "seconds": seconds,
        "chunks": len(chunks),
        "chunks_per_s": len(chunks) / seconds,
        "delivered_vertices": delivered,
        "vertices_per_s": delivered / seconds,
        "evictions": mm.eviction_count,
        "reloads": mm.reload_count,
    }


def run_engine(
    csr,
    feats,
    impl: str,
    hot_slots: int,
    chunk_vertices: int,
    seed: int,
    backend: str = "numpy",
    io_impl: str = "writeback",
    pipeline: str = "auto",
    trace=None,
):
    """Full run_layer on a real on-disk store.  ``impl`` selects BOTH the
    eviction-policy impl and the layer-tail impl (python = full scalar
    oracle baseline, array = the vectorized engine); ``io_impl`` selects
    the spill durability path (sync fsync-per-spill oracle vs async
    write-back + group commit); ``pipeline`` selects serial vs the
    double-buffered staging ring for device aggregation.  ``trace`` is a
    ``repro.obs.trace.Tracer`` to record the run's per-thread timeline
    into (plus the background RSS/disk sampler)."""
    d = feats.shape[1]
    specs = init_gnn_params("gcn", [d, 8], seed=seed)
    cfg = AtlasConfig(
        chunk_bytes=chunk_vertices * d * 4,
        hot_slots=hot_slots,
        eviction="at",
        policy_impl=impl,
        tail_impl=impl,
        backend=backend,
        io_impl=io_impl,
        pipeline=pipeline,
        seed=seed,
        sample_interval_s=0.05 if trace is not None else 0.0,
    )
    with tempfile.TemporaryDirectory() as td:
        store = GraphStore.create(td + "/store", csr, feats, num_partitions=4)
        session = AtlasSession(store, config=cfg, workdir=td + "/work",
                               trace=trace)
        t0 = time.perf_counter()
        result = session.infer(specs)
        seconds = time.perf_counter() - t0
        spills, metrics = result.final.spills, result.metrics
        out = spills_to_dense(spills, csr.num_vertices, specs[-1].out_dim)
    m = metrics[0]
    rec = {
        "impl": impl,
        "backend": backend,
        "io_impl": io_impl,
        "seconds": seconds,
        "chunks": m.chunks,
        "chunks_per_s": m.chunks / seconds,
        "vertices_per_s": csr.num_vertices / seconds,
        "evictions": m.evictions,
        "reloads": m.reloads,
        "reload_pct_mean": m.reload_pct_mean,
        "tail_seconds": m.tail_seconds,
        "tail_rows_per_s": m.tail_rows_per_s,
        "transform_seconds": m.transform_seconds,
        "spill_seconds": m.spill_seconds,
        "barrier_seconds": m.barrier_seconds,
        "bytes_inflight": m.bytes_inflight,
        "aggregate_seconds": m.aggregate_seconds,
        "h2d_seconds": m.h2d_seconds,
        "pipeline_stall_seconds": m.pipeline_stall_seconds,
        # run-wide I/O queue stats, captured by the session before the
        # scheduler closed (None under io_impl="sync": no queue exists)
        "queue_stats": result.queue_stats,
        "output": out,
    }
    if trace is not None:
        rec["telemetry"] = result.telemetry
    return rec


def capture_graduation_stream(csr, feats, hot_slots, chunk_vertices, seed):
    """One engine run with ``GraduationProcessor.add_gather`` shimmed to
    record the exact per-call id batches the delivery loop produces — the
    real layer-tail workload, replayed below under both tail impls."""
    from repro.core.graduation import GraduationProcessor

    batches: list[np.ndarray] = []
    orig = GraduationProcessor.add_gather

    def recording(self, ids, source, rows_index):
        batches.append(np.asarray(ids).copy())
        return orig(self, ids, source, rows_index)

    GraduationProcessor.add_gather = recording
    try:
        run_engine(csr, feats, "array", hot_slots, chunk_vertices, seed)
    finally:
        GraduationProcessor.add_gather = orig
    return batches


def run_tail_replay(batches, num_vertices: int, dim: int, hot_slots: int, seed: int):
    """Replay the captured graduation stream through both tail impls,
    single-threaded (no GIL cross-talk), and isolate the bookkeeping cost:
    total minus the dense transform and the physical spill write, which
    are identical work under either impl.  Asserts bit-identical output."""
    from repro.core.graduation import make_graduation
    from repro.storage.writer import EmbeddingWriter

    rng = np.random.default_rng(seed)
    hot = rng.standard_normal((hot_slots, dim)).astype(np.float32)
    slot_batches = [
        rng.integers(0, hot_slots, len(b)).astype(np.int64) for b in batches
    ]
    spec = init_gnn_params("gcn", [dim, 8], seed=seed)[0]
    from repro.models.gnn import layer_update

    results, outputs = {}, {}
    for impl in ("python", "array"):
        best = None
        for _ in range(3):
            with tempfile.TemporaryDirectory() as td:
                w = EmbeddingWriter(
                    td, num_vertices=num_vertices, dim=8, dtype=np.float32,
                    num_partitions=8, buffer_rows=4096,
                    threaded=False, ingest_impl=impl,
                )
                g = make_graduation(
                    impl, transform=lambda r: layer_update(spec, r),
                    sink=w.write, dim=dim, dtype=np.float32,
                    buffer_rows=8192, threaded=False,
                )
                t0 = time.perf_counter()
                for ids, slots in zip(batches, slot_batches):
                    g.add_gather(ids, hot, slots)
                g.close()
                spills = w.close()
                total = time.perf_counter() - t0
                book = total - g.transform_seconds - w.spill_seconds
                if best is None or book < best["tail_seconds"]:
                    best = {
                        "impl": impl,
                        "tail_seconds": book,
                        "tail_rows_per_s": num_vertices / book,
                        "total_seconds": total,
                        "transform_seconds": g.transform_seconds,
                        "spill_seconds": w.spill_seconds,
                    }
                if impl not in outputs:
                    outputs[impl] = spills_to_dense(spills, num_vertices, 8)
        results[impl] = best
    assert np.array_equal(outputs["python"], outputs["array"]), (
        "tail impls diverged (spill contents)"
    )
    return results


def report(title: str, results: dict) -> float:
    py, ar = results["python"], results["array"]
    assert py["evictions"] == ar["evictions"], "impls diverged (evictions)"
    assert py["reloads"] == ar["reloads"], "impls diverged (reloads)"
    speedup = py["seconds"] / ar["seconds"]
    print(f"\n== {title} ==")
    for r in (py, ar):
        print(
            f"  {r['impl']:<7} {r['seconds']:8.3f}s   "
            f"{r['chunks_per_s']:10.1f} chunks/s   "
            f"{r['vertices_per_s']:12.0f} vertices/s   "
            f"evictions={r['evictions']} reloads={r['reloads']}"
        )
    print(f"  speedup (array over python): {speedup:.2f}x")
    return speedup


def report_tail(results: dict) -> float:
    """Layer-tail (graduation bookkeeping + writer scatter) throughput
    from the single-threaded stream replay, excluding the dense transform
    and physical spill write that are identical work under either impl."""
    py, ar = results["python"], results["array"]
    tail_speedup = ar["tail_rows_per_s"] / py["tail_rows_per_s"]
    print("  -- layer tail (graduation + spill scatter), stream replay --")
    for r in (py, ar):
        print(
            f"  {r['impl']:<7} {r['tail_seconds']*1000:8.1f}ms tail   "
            f"{r['tail_rows_per_s']:12.0f} rows/s   "
            f"(transform {r['transform_seconds']:.3f}s, "
            f"spill {r['spill_seconds']:.3f}s)"
        )
    print(f"  tail speedup (array over python): {tail_speedup:.2f}x")
    return tail_speedup


def run_io_sweep(csr, feats, hot_fracs, chunk_vertices, seed, repeats):
    """sync-vs-writeback spill durability across a hot-store sweep.

    Per hot fraction: run the full engine under both io impls, assert the
    dense spill outputs are bit-identical, and report the spill cost left
    on the layer critical path (spill_seconds) with the group-commit
    barrier broken out separately — plus reload% so the sweep charts
    reload churn vs hot-store fraction like paper Fig 8."""
    best = lambda runs: min(runs, key=lambda r: r["seconds"])
    sweep = []
    for hf in hot_fracs:
        hot_slots = max(16, int(csr.num_vertices * hf))
        res = {}
        for io_impl in ("sync", "writeback"):
            res[io_impl] = best([
                run_engine(csr, feats, "array", hot_slots, chunk_vertices,
                           seed, io_impl=io_impl)
                for _ in range(repeats)
            ])
        out_s = res["sync"].pop("output")
        out_w = res["writeback"].pop("output")
        if not np.array_equal(out_s, out_w):
            raise AssertionError(
                f"io impls diverged (dense spill contents) at hot_frac={hf}"
            )
        assert res["sync"]["evictions"] == res["writeback"]["evictions"]
        sweep.append({"hot_frac": hf, "hot_slots": hot_slots, **res})
    return sweep


def report_io(sweep) -> None:
    print("\n== io (sync fsync-per-spill vs write-back group commit) ==")
    print(
        f"  {'hot_frac':>8} {'impl':>10} {'total':>9} {'spill(cp)':>10} "
        f"{'barrier':>9} {'inflight':>10} {'reload%':>8}"
    )
    for row in sweep:
        for impl in ("sync", "writeback"):
            r = row[impl]
            print(
                f"  {row['hot_frac']:>8.3f} {impl:>10} {r['seconds']:>8.3f}s "
                f"{r['spill_seconds']:>9.4f}s {r['barrier_seconds']:>8.4f}s "
                f"{r['bytes_inflight']:>10} {r['reload_pct_mean']:>7.1f}%"
            )
        sy, wb = row["sync"], row["writeback"]
        if wb["spill_seconds"] > 0:
            print(
                f"  {'':>8} critical-path spill time: "
                f"{sy['spill_seconds'] / wb['spill_seconds']:.1f}x lower "
                f"(writeback), spill contents bit-identical"
            )


def run_dist_check(v, d, kind, shards, workers, chunk_vertices, seed,
                   trace_path=None):
    """``--mode dist``: the shard-parallel engine vs the single-machine
    session on an exact-arithmetic graph (``repro.exact``) — every fp32
    sum exactly representable, so the N-shard run with cross-shard
    message routing must reproduce the single-machine spills **and** the
    rows served by the unmodified reader bit for bit.  Any tolerance here
    would hide a routing/namespace bug, so the comparison is
    ``np.array_equal``, not allclose."""
    import shutil

    from repro.dist import DistSession
    from repro.exact import exact_graph_and_specs

    csr, feats, specs = exact_graph_and_specs(v, d, kind=kind, seed=seed)
    hot_slots = max(16, v // 8)
    cfg = AtlasConfig(
        chunk_bytes=chunk_vertices * d * 4, hot_slots=hot_slots,
        trace=trace_path is not None,
    )
    with tempfile.TemporaryDirectory() as td:
        store = GraphStore.create(td + "/store", csr, feats, num_partitions=4)
        t0 = time.perf_counter()
        with AtlasSession(
            store,
            config=AtlasConfig(chunk_bytes=cfg.chunk_bytes, hot_slots=hot_slots),
            workdir=td + "/single",
        ) as single:
            ref = single.infer(specs)
            dense_ref = spills_to_dense(ref.final.spills, v, ref.final.dim)
        single_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        with DistSession(
            store, shards=shards, config=cfg, workers=workers,
            workdir=td + "/dist", trace=trace_path is not None,
        ) as dist:
            result = dist.infer(specs)
            dense_dist = spills_to_dense(result.final.spills, v, result.final.dim)
            version = dist.publish(result.final)
            probe = np.arange(0, v, 97)
            with dist.reader(result.final.layer) as reader:
                served = reader.lookup(probe)
            dist_s = time.perf_counter() - t0
            if trace_path and result.trace_path:
                shutil.copyfile(result.trace_path, trace_path)
    if not np.array_equal(dense_dist, dense_ref):
        raise AssertionError(
            f"dist spills diverged from single-machine ({kind}, "
            f"shards={shards}, workers={workers})"
        )
    if not np.array_equal(served, dense_ref[probe]):
        raise AssertionError(
            f"served rows diverged from single-machine ({kind}, "
            f"shards={shards}, workers={workers})"
        )
    exchange_bytes = sum(
        r["exchange"]["sent_bytes"]
        for reports in result.shard_reports.values()
        for r in reports
    )
    rec = {
        "kind": kind,
        "vertices": v,
        "shards": shards,
        "workers": workers,
        "layers": len(specs),
        "epoch": version.epoch,
        "single_seconds": single_s,
        "dist_seconds": dist_s,
        "exchange_sent_bytes": exchange_bytes,
        "bit_identical": True,
        "served_identical": True,
    }
    print(
        f"  {kind:<5} shards={shards} workers={workers}: "
        f"single {single_s:6.2f}s  dist {dist_s:6.2f}s  "
        f"exchange {exchange_bytes} B  spills+served bit-identical"
    )
    return rec


def build_features(args, workdir: str):
    """Dense in-RAM features, or an on-disk memmap for multi-M graphs."""
    if args.mmap_features or args.vertices >= 1_000_000:
        path = os.path.join(workdir, "features.npy")
        print(f"features: memory-mapped {path}")
        return make_features_mmap(
            args.vertices, args.dim, path, seed=args.seed
        )
    return make_features(args.vertices, args.dim, seed=args.seed)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--vertices", type=int, default=120_000)
    ap.add_argument("--avg-degree", type=int, default=8)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--hot-frac", type=float, default=0.125,
                    help="hot slots as a fraction of vertices")
    ap.add_argument("--hot-fracs", default=None,
                    help="comma list of hot fractions for --mode io "
                         "(default: just --hot-frac)")
    ap.add_argument("--chunk-vertices", type=int, default=4096)
    ap.add_argument("--mode",
                    choices=["micro", "engine", "both", "backend", "io",
                             "dist"],
                    default="micro")
    ap.add_argument("--shards", type=int, default=2,
                    help="shard count for --mode dist")
    ap.add_argument("--dist-workers", default="process",
                    choices=["thread", "process"],
                    help="worker harness for --mode dist")
    ap.add_argument("--dist-kinds", default="gcn,sage",
                    help="comma list of GNN kinds for --mode dist")
    ap.add_argument("--backend", default="numpy",
                    choices=["numpy", "jax", "pallas", "pallas-interpret"],
                    help="chunk-aggregation backend for --mode engine and "
                         "the non-numpy leg of --mode backend")
    ap.add_argument("--pipeline", default="auto",
                    choices=["auto", "staged", "serial"],
                    help="aggregation pipeline for --mode engine runs "
                         "(auto = staged when threaded and backend != numpy)")
    ap.add_argument("--io-impl", default="writeback",
                    choices=["writeback", "sync"],
                    help="spill durability impl for --mode engine runs")
    ap.add_argument("--mmap-features", action="store_true",
                    help="generate features into an on-disk .npy memmap "
                         "(auto at --vertices >= 1M)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="repetitions per impl; best (min-time) run is reported")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write raw results as JSON to PATH ('-' for stdout)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="run one extra traced engine pass and export its "
                         "Perfetto timeline (Chrome trace-event JSON) to "
                         "PATH; inspect with repro.launch.obs_report")
    args = ap.parse_args()

    hot_slots = max(16, int(args.vertices * args.hot_frac))
    print(
        f"graph: V={args.vertices} avg_deg={args.avg_degree} d={args.dim} "
        f"hot_slots={hot_slots} chunk_vertices={args.chunk_vertices}"
    )
    csr = powerlaw_graph(args.vertices, args.avg_degree, seed=args.seed,
                         self_loops=True)
    all_results = {}
    best = lambda runs: min(runs, key=lambda r: r["seconds"])
    reps = max(1, args.repeats)
    feat_td = tempfile.TemporaryDirectory(prefix="bench_delivery_feats_")
    feats_cache: list = []  # built once, shared by every mode leg

    def get_feats():
        if not feats_cache:
            feats_cache.append(build_features(args, feat_td.name))
        return feats_cache[0]

    if args.mode in ("micro", "both"):
        chunks = build_chunks(csr, args.chunk_vertices)
        res = {
            impl: best([
                run_micro(csr, chunks, impl, hot_slots, args.dim, args.seed)
                for _ in range(reps)
            ])
            for impl in ("python", "array")
        }
        all_results["micro"] = {**res, "speedup": report("micro (_deliver only)", res)}
    if args.mode in ("engine", "both"):
        feats = get_feats()
        res = {
            impl: best([
                run_engine(csr, feats, impl, hot_slots, args.chunk_vertices,
                           args.seed, backend=args.backend,
                           io_impl=args.io_impl, pipeline=args.pipeline)
                for _ in range(reps)
            ])
            for impl in ("python", "array")
        }
        # the array tail must reproduce the python-oracle spills bit for bit
        out_py, out_ar = res["python"].pop("output"), res["array"].pop("output")
        if not np.array_equal(out_py, out_ar):
            raise AssertionError("impls diverged (spill contents)")
        speedup = report("engine (full run_layer)", res)
        print("  spill contents: bit-identical across impls")
        ar = res["array"]
        print(
            f"  pipeline: aggregate {ar['aggregate_seconds']:.4f}s   "
            f"h2d {ar['h2d_seconds']:.4f}s   "
            f"stall {ar['pipeline_stall_seconds']:.4f}s"
        )
        # the staging ring must reproduce the serial spills bit for bit
        if args.backend != "numpy":
            out_st = run_engine(
                csr, feats, "array", hot_slots, args.chunk_vertices,
                args.seed, backend=args.backend, io_impl=args.io_impl,
                pipeline="staged",
            ).pop("output")
            out_se = run_engine(
                csr, feats, "array", hot_slots, args.chunk_vertices,
                args.seed, backend=args.backend, io_impl=args.io_impl,
                pipeline="serial",
            ).pop("output")
            if not np.array_equal(out_st, out_se):
                raise AssertionError(
                    "pipeline impls diverged (spill contents)"
                )
            print("  spill contents: bit-identical staged vs serial pipeline")
        # layer-tail throughput: replay the engine's real graduation
        # stream through both tail impls, single-threaded and isolated
        batches = capture_graduation_stream(
            csr, feats, hot_slots, args.chunk_vertices, args.seed
        )
        tail = run_tail_replay(
            batches, args.vertices, args.dim, hot_slots, args.seed
        )
        tail_speedup = report_tail(tail)
        print("  tail replay spill contents: bit-identical across impls")
        all_results["engine"] = {
            **res, "speedup": speedup,
            "tail": tail, "tail_speedup": tail_speedup,
        }
    if args.mode == "io":
        # ISSUE 5: spill durability impls across a hot-store sweep, with
        # the vectorized engine fixed so only io_impl varies
        feats = get_feats()
        hot_fracs = (
            [float(x) for x in args.hot_fracs.split(",")]
            if args.hot_fracs
            else [args.hot_frac]
        )
        sweep = run_io_sweep(
            csr, feats, hot_fracs, args.chunk_vertices, args.seed, reps
        )
        report_io(sweep)
        print("  spill contents: bit-identical across io impls")
        all_results["io"] = sweep
    if args.mode == "backend":
        # ROADMAP item: numpy vs device chunk aggregation end-to-end, with
        # the array policy impl fixed so only the aggregation backend varies
        feats = get_feats()
        other = args.backend if args.backend != "numpy" else "jax"
        res = {
            backend: best([
                run_engine(csr, feats, "array", hot_slots, args.chunk_vertices,
                           args.seed, backend=backend)
                for _ in range(reps)
            ])
            for backend in ("numpy", other)
        }
        ny, dv = res["numpy"], res[other]
        # backends differ in float op order: same bookkeeping, not bitwise
        ny.pop("output"), dv.pop("output")
        assert ny["evictions"] == dv["evictions"], "backends diverged (evictions)"
        speedup = ny["seconds"] / dv["seconds"]
        print("\n== backend (full run_layer, policy_impl=array) ==")
        for r in (ny, dv):
            print(
                f"  {r['backend']:<16} {r['seconds']:8.3f}s   "
                f"{r['chunks_per_s']:10.1f} chunks/s   "
                f"{r['vertices_per_s']:12.0f} vertices/s   "
                f"evictions={r['evictions']} reloads={r['reloads']}"
            )
        print(
            f"  device leg: aggregate {dv['aggregate_seconds']:.4f}s   "
            f"h2d {dv['h2d_seconds']:.4f}s   "
            f"stall {dv['pipeline_stall_seconds']:.4f}s"
        )
        print(f"  speedup ({other} over numpy): {speedup:.2f}x")
        all_results["backend"] = {**res, f"{other}_speedup": speedup}
    if args.mode == "dist":
        # ISSUE 9: shard-parallel engine vs single-machine, exact
        # arithmetic, bitwise assertion on spills AND served rows; the
        # merged per-shard trace (when --trace) lands at args.trace
        print(f"\n== dist (shard-parallel vs single-machine, "
              f"shards={args.shards}) ==")
        kinds = [k for k in args.dist_kinds.split(",") if k]
        dist_rows = []
        for i, kind in enumerate(kinds):
            dist_rows.append(run_dist_check(
                args.vertices, args.dim, kind, args.shards,
                args.dist_workers, args.chunk_vertices, args.seed,
                trace_path=args.trace if i == 0 else None,
            ))
        print("  spills + served rows: bit-identical across all kinds")
        all_results["dist"] = dist_rows
        if args.trace:
            print(f"merged per-shard trace -> {args.trace}")
    if args.trace and args.mode != "dist":
        # one extra traced pass of the full engine (vectorized impl):
        # per-thread timeline + telemetry, Perfetto-loadable, analysable
        # with `python -m repro.launch.obs_report <trace> --check`
        from repro.obs.trace import Tracer

        tracer = Tracer()
        traced = run_engine(
            csr, get_feats(), "array", hot_slots, args.chunk_vertices,
            args.seed, backend=args.backend, io_impl=args.io_impl,
            pipeline=args.pipeline, trace=tracer,
        )
        traced.pop("output")
        path = tracer.export(args.trace)
        print(
            f"\ntraced engine pass: {traced['seconds']:.3f}s, "
            f"{tracer.num_spans} spans -> {path}"
        )
        all_results["traced"] = traced
    feat_td.cleanup()
    if args.json == "-":
        print(json.dumps(all_results, indent=2))
    elif args.json:
        with open(args.json, "w") as f:
            json.dump(all_results, f, indent=2)
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()
