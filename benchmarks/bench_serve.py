"""Serving-path benchmark: batched vertex lookups against published layers.

Builds an engine-shaped spill set (every vertex exactly once, scattered
across overlapping sorted files), publishes it through the
``AtlasSession`` lifecycle (versioned compaction into block-indexed
servable files), then measures pinned ``session.reader`` lookups under
uniform and Zipfian batched workloads across a sweep of page-cache
budgets (0 = cache disabled) plus one **zero-copy mmap fast-path** row
per workload (``fast_path=True``: rows gathered straight from the file
mmaps, the OS page cache is the cache).  Reports queries/s, rows/s,
cache hit rate, disk blocks read, and the reader's cache counters as
seen through the obs ``MetricsRegistry``, as JSON with ``--json``.

``--concurrent N`` switches to the MVCC smoke mode instead: N reader
threads hammer ``session.reader(...).lookup`` while the main thread
re-publishes the layer in a loop with alternating row contents; every
batch is checked bit-for-bit against the reader's pinned version, so any
mixed-version or missing row fails the run.

``--processes 1,2,4`` runs the multi-process serving benchmark: for
each reader count, that many *forked processes* each open their own
``AtlasSession`` over the shared store (pinning via cross-process
leases), verify their first batches bit-for-bit against a
``fast_path=False`` oracle reader, then run the timed workload with a
per-reader latency histogram — merged in the parent into aggregate
p50/p99 (``Histogram.to_state`` crosses the pipe) alongside aggregate
q/s.  ``--target-qps`` paces each reader on a fixed schedule instead of
running flat out.

``--orderings og,rnd,at`` switches to the layout-sensitivity mode: one
real graph store per ordering (``GraphStore.create(order=...)``), the
store's layer-0 rows published as the servable layer, and a *popularity*
workload (Zipf over the in-degree ranking, i.e. hubs are hot) replayed
against each store **by external id** — the reader translates through
the permutation sidecar, so all three stores serve bit-identical rows
and only the physical layout differs.  Reports the page-cache hit rate
per ordering: the paper's greedy order packs hubs into few blocks, so
its hit rate should lead under a small cache.

Usage:
    PYTHONPATH=src python benchmarks/bench_serve.py                # 1M rows
    PYTHONPATH=src python benchmarks/bench_serve.py --vertices 200000 \
        --batches 500 --cache-mb 0,16 --json out.json              # CI scale
    PYTHONPATH=src python benchmarks/bench_serve.py --vertices 50000 \
        --concurrent 4 --publishes 8 --json concurrent.json        # smoke

Acceptance targets: >= 10x throughput for a Zipfian workload with a warm
cache vs cache disabled on a >= 1M-vertex store (ISSUE 2); zero
mixed-version rows under concurrent re-publication (ISSUE 4).
"""

from __future__ import annotations

import argparse
import json
import multiprocessing
import os
import tempfile
import threading
import time

import numpy as np

from repro.graphs.csr import CSRGraph
from repro.graphs.synth import make_features, powerlaw_graph
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.session import AtlasSession
from repro.storage.iostats import IOStats
from repro.storage.layout import GraphStore
from repro.storage.spill import SpillSet, write_spill

SERVE_LAYER = 1  # the layer number the benchmark publishes under


def latency_ms(hist: Histogram) -> dict:
    """Per-batch latency summary in milliseconds from a seconds-valued
    log-bucket histogram (quantiles interpolated within buckets)."""
    s = hist.snapshot()
    return {
        "count": s["count"],
        "mean_ms": round(s["mean"] * 1e3, 4),
        "max_ms": round(s["max"] * 1e3, 4),
        "p50_ms": round(s["p50"] * 1e3, 4),
        "p95_ms": round(s["p95"] * 1e3, 4),
        "p99_ms": round(s["p99"] * 1e3, 4),
    }


def build_spillset(
    root: str, vertices: int, dim: int, raw_files: int, seed: int, shift: float = 0.0
) -> tuple[SpillSet, np.ndarray]:
    """Write an overlapping raw spill set — the same shape the engine
    leaves behind.  ``shift`` offsets every row so alternating publishes
    are distinguishable bit-for-bit."""
    rng = np.random.default_rng(seed)
    rows = rng.standard_normal((vertices, dim)).astype(np.float32)
    if shift:
        rows += np.float32(shift)
    perm = rng.permutation(vertices)
    os.makedirs(root, exist_ok=True)
    ss = SpillSet()
    bounds = np.linspace(0, vertices, raw_files + 1).astype(int)
    for i in range(raw_files):
        sel = perm[bounds[i] : bounds[i + 1]]
        ss.add(
            write_spill(
                os.path.join(root, f"raw{i:03d}.spill"),
                sel.astype(np.uint64),
                rows[sel],
            )
        )
    return ss, rows


def make_session(root: str, vertices: int) -> AtlasSession:
    """A serving-only session over a minimal store (trivial topology,
    1-wide zero features): the benchmark publishes raw spill sets, so no
    engine run is involved."""
    csr = CSRGraph(
        indptr=np.zeros(vertices + 1, dtype=np.int64),
        indices=np.empty(0, dtype=np.int64),
    )
    store = GraphStore.create(
        os.path.join(root, "store"),
        csr,
        np.zeros((vertices, 1), dtype=np.float32),
        num_partitions=1,
    )
    return AtlasSession(store, workdir=os.path.join(root, "run"))


def make_workload(
    kind: str, vertices: int, batches: int, batch: int, alpha: float, seed: int
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        return rng.integers(0, vertices, size=(batches, batch))
    if kind == "zipf":
        # rank == vertex id: ATLAS reorders hubs first, so popularity-by-id
        # is the natural serving layout
        return (rng.zipf(alpha, size=(batches, batch)) - 1) % vertices
    raise ValueError(kind)


def run_workload(
    session: AtlasSession,
    queries: np.ndarray,
    cache_bytes: int,
    num_shards: int,
    warm_batches: int,
    fast_path: bool | str = False,
) -> dict:
    registry = MetricsRegistry()
    with session.reader(
        SERVE_LAYER, cache_bytes=cache_bytes, num_shards=num_shards,
        fast_path=fast_path, metrics=registry,
    ) as eng:
        for q in queries[:warm_batches]:
            eng.lookup(q)
        timed = queries[warm_batches:]
        hist = Histogram()
        t0 = time.perf_counter()
        for q in timed:
            b0 = time.perf_counter()
            eng.lookup(q)
            hist.observe(time.perf_counter() - b0)
        seconds = time.perf_counter() - t0
        rec = {
            "cache_mb": cache_bytes / (1 << 20),
            "fast_path": eng.fast_path,
            "batches": len(timed),
            "batch": queries.shape[1],
            "seconds": round(seconds, 4),
            "queries_per_s": round(len(timed) / seconds, 1),
            "rows_per_s": round(len(timed) * queries.shape[1] / seconds, 1),
            "disk_blocks_read": eng.blocks_read,
            "disk_bytes_read": eng.stats.bytes_read,
            "version": eng.version,
            "latency": latency_ms(hist),
        }
        if eng.cache is not None:
            rec["hit_rate"] = round(eng.cache.hit_rate(), 4)
            rec["resident_mb"] = round(eng.cache.resident_bytes / (1 << 20), 2)
            # the same counters as exported through the obs registry
            # (what obs_report / CI artifacts consume)
            rec["cache_counters"] = (
                registry.snapshot().get("serve", {}).get("cache", {})
            )
    return rec


# --------------------------------------------------------------------------
# Concurrent smoke mode (ISSUE 4): readers hammer session.reader during a
# re-publish loop; every batch must be bit-identical to the reader's pinned
# version — never mixed, never missing.
# --------------------------------------------------------------------------


def run_concurrent(
    session: AtlasSession,
    spillsets: list[SpillSet],
    refs: list[np.ndarray],
    args,
) -> dict:
    vertices = refs[0].shape[0]
    stop = threading.Event()
    errors: list[str] = []
    lookups = [0] * args.concurrent
    rows_checked = [0] * args.concurrent
    # one histogram per reader (no lock contention in the hot loop),
    # merged into a single latency distribution at the end
    hists = [Histogram() for _ in range(args.concurrent)]

    def expected(version: int) -> np.ndarray:
        # publish i (1-based epoch) carries variant (epoch-1) % len(refs)
        return refs[(version - 1) % len(refs)]

    def reader_loop(ti: int) -> None:
        rng = np.random.default_rng(1000 + ti)
        try:
            while not stop.is_set():
                with session.reader(
                    SERVE_LAYER,
                    cache_bytes=int(args.cache_mb_concurrent * (1 << 20)),
                    num_shards=args.shards,
                ) as eng:
                    ref = expected(eng.version)
                    for _ in range(args.batches_per_open):
                        q = rng.integers(0, vertices, size=args.batch)
                        b0 = time.perf_counter()
                        got = eng.lookup(q)
                        hists[ti].observe(time.perf_counter() - b0)
                        if not np.array_equal(got, ref[q]):
                            errors.append(
                                f"reader {ti}: rows diverged from pinned "
                                f"version v{eng.version}"
                            )
                            stop.set()
                            return
                        lookups[ti] += 1
                        rows_checked[ti] += len(q)
        except Exception as e:  # noqa: BLE001 - smoke harness surfaces all
            errors.append(f"reader {ti}: {type(e).__name__}: {e}")
            stop.set()

    # first publish before readers start so version 1 exists
    session.publish(SERVE_LAYER, spills=spillsets[0],
                    block_rows=args.block_rows,
                    rows_per_file=args.rows_per_file)
    threads = [
        threading.Thread(target=reader_loop, args=(ti,), daemon=True)
        for ti in range(args.concurrent)
    ]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    gc_removed = 0
    publishes = 1
    for i in range(1, args.publishes):
        if stop.is_set():
            break
        pub = session.publish(
            SERVE_LAYER,
            spills=spillsets[i % len(spillsets)],
            block_rows=args.block_rows,
            rows_per_file=args.rows_per_file,
        )
        publishes += 1
        gc_removed += len(pub.gc_removed)
    # let readers run a beat against the final version before stopping
    time.sleep(args.drain_seconds)
    stop.set()
    for ti, t in enumerate(threads):
        t.join(timeout=60)
        if t.is_alive():
            errors.append(f"reader {ti} failed to stop (possible deadlock)")
    seconds = time.perf_counter() - t0
    gc_removed += len(session.gc(SERVE_LAYER))
    merged = Histogram()
    for h in hists:
        merged.merge(h)
    rec = {
        "readers": args.concurrent,
        "publishes": publishes,
        "seconds": round(seconds, 3),
        "lookups": int(sum(lookups)),
        "rows_checked": int(sum(rows_checked)),
        "queries_per_s": round(sum(lookups) / seconds, 1),
        "versions_gc_removed": gc_removed,
        "versions_remaining": session.store.servable_versions(SERVE_LAYER),
        "latency": latency_ms(merged),
        "errors": errors,
    }
    if errors:
        raise AssertionError(f"concurrent serving smoke failed: {errors}")
    if not sum(lookups):
        raise AssertionError("concurrent serving smoke performed no lookups")
    return rec


# --------------------------------------------------------------------------
# Multi-process mode (ISSUE 10): N forked reader processes, each with its
# own AtlasSession over the shared store (cross-process lease pins), each
# verified against the fast_path=False oracle, latency histograms merged
# in the parent.
# --------------------------------------------------------------------------


def _mp_reader_worker(store_root: str, conn, cfg: dict, barrier) -> None:
    """One benchmark reader process: open a session over the shared
    store, verify the first batches bit-for-bit against the page-cache
    oracle, warm up, rendezvous on ``barrier`` so every reader's timed
    loop overlaps, then run the timed workload.  Ships its counters and
    the latency histogram state back over ``conn``."""
    out: dict = {"pid": os.getpid(), "mismatches": 0, "error": None}
    try:
        queries = make_workload(
            cfg["workload"], cfg["vertices"],
            cfg["batches"] + cfg["warm_batches"], cfg["batch"],
            cfg["alpha"], cfg["seed"],
        )
        with AtlasSession(store_root) as session:
            with session.reader(
                SERVE_LAYER,
                cache_bytes=cfg["cache_bytes"] or None,
                num_shards=cfg["shards"],
                fast_path=cfg["fast_path"],
            ) as eng:
                # bit-identity vs the decoded-block oracle, outside the
                # timed loop (oracle reads are the slow path by design)
                if cfg["verify_batches"]:
                    with session.reader(
                        SERVE_LAYER, fast_path=False
                    ) as oracle:
                        for q in queries[: cfg["verify_batches"]]:
                            if not np.array_equal(
                                eng.lookup(q), oracle.lookup(q)
                            ):
                                out["mismatches"] += 1
                out["verified_batches"] = int(cfg["verify_batches"])
                for q in queries[: cfg["warm_batches"]]:
                    eng.lookup(q)
                barrier.wait(timeout=120)
                timed = queries[cfg["warm_batches"]:]
                hist = Histogram()
                interval = (
                    1.0 / cfg["target_qps"] if cfg["target_qps"] > 0 else 0.0
                )
                busy = 0.0
                t0 = time.perf_counter()
                for k, q in enumerate(timed):
                    if interval:
                        # fixed schedule (no coordinated omission: late
                        # batches do not push later ones back)
                        due = t0 + k * interval
                        delay = due - time.perf_counter()
                        if delay > 0:
                            time.sleep(delay)
                    b0 = time.perf_counter()
                    eng.lookup(q)
                    dt = time.perf_counter() - b0
                    busy += dt
                    hist.observe(dt)
                out.update(
                    wall_s=time.perf_counter() - t0,
                    busy_s=busy,
                    lookups=len(timed),
                    rows=int(len(timed) * cfg["batch"]),
                    fast_path=bool(eng.fast_path),
                    version=int(eng.version),
                    disk_blocks_read=int(eng.blocks_read),
                    hist=hist.to_state(),
                )
    except BaseException as e:  # noqa: BLE001 - report, parent raises
        out["error"] = f"{type(e).__name__}: {e}"
    conn.send(out)
    conn.close()


def run_multiprocess(td: str, args) -> dict:
    """Fork-per-reader serving benchmark across ``--processes`` counts."""
    root = os.path.join(td, "mp")
    session = make_session(root, args.vertices)
    ss, _ = build_spillset(
        os.path.join(root, "raw"), args.vertices, args.dim,
        args.raw_files, args.seed,
    )
    session.publish(SERVE_LAYER, spills=ss, block_rows=args.block_rows,
                    rows_per_file=args.rows_per_file)
    store_root = session.store.root
    session.close()  # children open their own sessions over the store

    fast = {"auto": "auto", "true": True, "false": False}[args.mp_fast_path]
    counts = [int(x) for x in args.processes.split(",")]
    ctx = multiprocessing.get_context("fork")
    sweep = []
    for n in counts:
        pipes, procs = [], []
        barrier = ctx.Barrier(n)  # aligns every reader's timed window
        t0 = time.perf_counter()
        for i in range(n):
            cfg = {
                "workload": args.mp_workload,
                "vertices": args.vertices,
                "dim": args.dim,
                "batch": args.batch,
                "batches": args.batches,
                "warm_batches": args.warm_batches,
                "alpha": args.zipf_alpha,
                "seed": args.seed + 100 + i,
                "cache_bytes": int(args.cache_mb_concurrent * (1 << 20)),
                "shards": args.shards,
                "fast_path": fast,
                "verify_batches": args.verify_batches,
                "target_qps": args.target_qps,
            }
            parent_conn, child_conn = ctx.Pipe(duplex=False)
            p = ctx.Process(
                target=_mp_reader_worker,
                args=(store_root, child_conn, cfg, barrier),
                daemon=True,
            )
            p.start()
            child_conn.close()
            pipes.append(parent_conn)
            procs.append(p)
        reports = [c.recv() for c in pipes]
        for p in procs:
            p.join(timeout=120)
        wall = time.perf_counter() - t0
        errors = [r["error"] for r in reports if r["error"]]
        if errors:
            raise AssertionError(f"multi-process readers failed: {errors}")
        mismatches = sum(r["mismatches"] for r in reports)
        if mismatches:
            raise AssertionError(
                f"{mismatches} batches diverged from the fast_path=False "
                f"oracle across {n} reader processes"
            )
        merged = Histogram()
        for r in reports:
            merged.merge(Histogram.from_state(r["hist"]))
        lookups = sum(r["lookups"] for r in reports)
        # aggregate throughput over the concurrent measurement window:
        # the slowest reader's timed loop (startup/fork/publish overhead
        # is reported separately as total_wall_s)
        window = max(r["wall_s"] for r in reports)
        rec = {
            "processes": n,
            "fast_path": reports[0]["fast_path"],
            "workload": args.mp_workload,
            "target_qps": args.target_qps,
            "lookups": lookups,
            "rows": sum(r["rows"] for r in reports),
            "verified_batches": sum(r["verified_batches"] for r in reports),
            "wall_s": round(window, 3),
            "total_wall_s": round(wall, 3),
            "queries_per_s": round(lookups / window, 1),
            "per_reader_qps": round(
                sum(r["lookups"] / r["busy_s"] for r in reports if r["busy_s"])
                / n, 1,
            ),
            "disk_blocks_read": sum(r["disk_blocks_read"] for r in reports),
            "latency": latency_ms(merged),
        }
        sweep.append(rec)
        lat = rec["latency"]
        print(f"  processes={n:<3d} fast_path={rec['fast_path']!s:<5} "
              f"{rec['queries_per_s']:>10.1f} q/s agg  "
              f"p50={lat['p50_ms']:.3f}ms p99={lat['p99_ms']:.3f}ms  "
              f"({rec['verified_batches']} batches oracle-verified)")
    return {"sweep": sweep, "store_root": store_root}


# --------------------------------------------------------------------------
# Ordering mode (ISSUE 8): same rows, same external-id workload, three
# physical layouts — how much page-cache hit rate does the store ordering
# buy on a hub-heavy (popularity-Zipf) serving workload?
# --------------------------------------------------------------------------


def run_orderings(td: str, args) -> list[dict]:
    csr = powerlaw_graph(args.vertices, args.degree, seed=args.seed)
    feats = make_features(args.vertices, args.dim, seed=args.seed + 1)
    # popularity follows citation count: rank vertices by in-degree and
    # draw Zipf ranks, so the hot set is the graph's hub set
    indeg = np.bincount(np.asarray(csr.indices), minlength=csr.num_vertices)
    by_pop = np.argsort(-indeg, kind="stable")
    rng = np.random.default_rng(args.seed + 2)
    ranks = (rng.zipf(args.zipf_alpha,
                      size=(args.batches + args.warm_batches, args.batch)) - 1)
    queries = by_pop[ranks % args.vertices]  # external ids, hub-hot
    cache_bytes = int(args.cache_mb_ordering * (1 << 20))
    rows = []
    for ordering in args.orderings.split(","):
        root = os.path.join(td, f"ord_{ordering}")
        store = GraphStore.create(
            os.path.join(root, "store"), csr, feats, num_partitions=4,
            order=ordering, order_seed=args.seed,
        )
        with AtlasSession(store, workdir=os.path.join(root, "run")) as session:
            session.publish(SERVE_LAYER, spills=store.layer0_spills(),
                            block_rows=args.block_rows,
                            rows_per_file=args.rows_per_file)
            rec = run_workload(session, queries, cache_bytes,
                               args.shards, args.warm_batches)
        rec = {"ordering": store.ordering_name, **rec}
        rows.append(rec)
        print(f"  order={store.ordering_name:<8} cache={args.cache_mb_ordering:5.1f}MiB  "
              f"hit_rate={rec.get('hit_rate', 0.0):<7} "
              f"blocks_read={rec['disk_blocks_read']:<8d} "
              f"{rec['queries_per_s']:>10.1f} q/s")
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--vertices", type=int, default=1_000_000)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--raw-files", type=int, default=8)
    ap.add_argument("--rows-per-file", type=int, default=1 << 18)
    ap.add_argument("--block-rows", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--batches", type=int, default=2000)
    ap.add_argument("--warm-batches", type=int, default=500)
    ap.add_argument("--zipf-alpha", type=float, default=1.1)
    ap.add_argument("--cache-mb", default="0,8,64",
                    help="comma-separated page-cache budgets in MiB (0 = off)")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--workloads", default="zipf,uniform")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--concurrent", type=int, default=0, metavar="N",
                    help="smoke mode: N reader threads during a re-publish "
                         "loop (skips the cache sweep)")
    ap.add_argument("--publishes", type=int, default=8,
                    help="re-publications in --concurrent mode")
    ap.add_argument("--batches-per-open", type=int, default=20,
                    help="lookups per pinned reader in --concurrent mode")
    ap.add_argument("--cache-mb-concurrent", type=float, default=8.0,
                    help="per-reader cache budget in --concurrent mode")
    ap.add_argument("--drain-seconds", type=float, default=1.0,
                    help="reader time against the final version before stop")
    ap.add_argument("--processes", default="", metavar="1,2,4",
                    help="multi-process mode: comma-separated reader-process "
                         "counts, each forked with its own AtlasSession")
    ap.add_argument("--mp-workload", default="zipf",
                    choices=("zipf", "uniform"),
                    help="workload kind in --processes mode")
    ap.add_argument("--mp-fast-path", default="true",
                    choices=("auto", "true", "false"),
                    help="serving path in --processes mode")
    ap.add_argument("--target-qps", type=float, default=0.0,
                    help="per-reader pacing in --processes mode (0 = flat out)")
    ap.add_argument("--verify-batches", type=int, default=8,
                    help="batches each process checks against the "
                         "fast_path=False oracle before timing")
    ap.add_argument("--orderings", default="", metavar="OG,RND,AT",
                    help="layout mode: comma-separated store orderings to "
                         "compare under a popularity workload (skips the "
                         "cache sweep)")
    ap.add_argument("--degree", type=int, default=12,
                    help="avg degree of the graph in --orderings mode")
    ap.add_argument("--cache-mb-ordering", type=float, default=4.0,
                    help="page-cache budget in --orderings mode (small, so "
                         "layout matters)")
    ap.add_argument("--json", default=None, help="write results to this path")
    args = ap.parse_args()

    results = {
        "config": {
            k: getattr(args, k)
            for k in ("vertices", "dim", "block_rows", "batch", "batches",
                      "warm_batches", "zipf_alpha", "shards", "concurrent",
                      "publishes")
        }
    }
    with tempfile.TemporaryDirectory() as td:
        if args.orderings:
            print(f"ordering mode: V={args.vertices} d={args.dim} "
                  f"deg={args.degree} orderings={args.orderings} "
                  f"cache={args.cache_mb_ordering}MiB")
            results["orderings"] = run_orderings(td, args)
            if args.json:
                with open(args.json, "w") as f:
                    json.dump(results, f, indent=2)
                print(f"wrote {args.json}")
            return
        if args.processes:
            print(f"multi-process mode: V={args.vertices} d={args.dim} "
                  f"processes={args.processes} workload={args.mp_workload} "
                  f"fast_path={args.mp_fast_path}"
                  + (f" target_qps={args.target_qps}" if args.target_qps
                     else ""))
            mp_res = run_multiprocess(td, args)
            results["processes"] = mp_res["sweep"]
            qps = [r["queries_per_s"] for r in mp_res["sweep"]]
            if len(qps) > 1:
                results["process_scaling"] = {
                    str(r["processes"]): r["queries_per_s"]
                    for r in mp_res["sweep"]
                }
                print(f"  aggregate scaling: "
                      + " -> ".join(f"{q:.0f}" for q in qps) + " q/s")
            if args.concurrent <= 0:
                if args.json:
                    with open(args.json, "w") as f:
                        json.dump(results, f, indent=2)
                    print(f"wrote {args.json}")
                return
        session = make_session(td, args.vertices)
        if args.concurrent > 0:
            print(f"concurrent smoke: V={args.vertices} d={args.dim} "
                  f"{args.concurrent} readers x {args.publishes} publishes")
            variants = []
            refs = []
            for k in range(2):
                ss, rows = build_spillset(
                    os.path.join(td, f"raw{k}"), args.vertices, args.dim,
                    args.raw_files, args.seed, shift=float(k),
                )
                variants.append(ss)
                refs.append(rows)
            rec = run_concurrent(session, variants, refs, args)
            results["concurrent"] = rec
            lat = rec["latency"]
            print(f"  {rec['lookups']} lookups ({rec['rows_checked']} rows "
                  f"bit-checked) across {rec['publishes']} publishes in "
                  f"{rec['seconds']}s -> {rec['queries_per_s']} q/s, "
                  f"{rec['versions_gc_removed']} stale versions GC'd, "
                  f"remaining {rec['versions_remaining']}")
            print(f"  per-batch latency: p50={lat['p50_ms']:.3f}ms "
                  f"p95={lat['p95_ms']:.3f}ms p99={lat['p99_ms']:.3f}ms "
                  f"(max {lat['max_ms']:.3f}ms over {lat['count']} batches)")
        else:
            print(f"building servable store: V={args.vertices} d={args.dim} "
                  f"({args.vertices * args.dim * 4 >> 20} MiB rows)")
            t0 = time.perf_counter()
            ss, _ = build_spillset(
                os.path.join(td, "raw"), args.vertices, args.dim,
                args.raw_files, args.seed,
            )
            write_s = time.perf_counter() - t0
            stats = IOStats()
            t0 = time.perf_counter()
            pub = session.publish(
                SERVE_LAYER, spills=ss, rows_per_file=args.rows_per_file,
                block_rows=args.block_rows, stats=stats,
            )
            results["build"] = {
                "raw_write_s": round(write_s, 2),
                "compact_s": round(time.perf_counter() - t0, 2),
                "compact_bytes_read": stats.bytes_read,
                "compact_bytes_written": stats.bytes_written,
                "servable_files": len(pub.files),
                "version": pub.epoch,
            }
            print(f"  raw write {write_s:.2f}s, compaction "
                  f"{results['build']['compact_s']}s -> {len(pub.files)} files "
                  f"(version v{pub.epoch})")
            budgets = [float(x) for x in args.cache_mb.split(",")]
            for kind in args.workloads.split(","):
                queries = make_workload(
                    kind, args.vertices, args.batches + args.warm_batches,
                    args.batch, args.zipf_alpha, args.seed + 1,
                )
                rows = []
                for mb in budgets:
                    rec = run_workload(
                        session, queries, int(mb * (1 << 20)),
                        args.shards, args.warm_batches,
                    )
                    rows.append(rec)
                    extra = (f"hit_rate={rec['hit_rate']}" if "hit_rate" in rec
                             else "cache off")
                    lat = rec["latency"]
                    print(f"  {kind:<8} cache={mb:6.1f}MiB  "
                          f"{rec['queries_per_s']:>10.1f} q/s  "
                          f"{rec['rows_per_s']:>12.1f} rows/s  "
                          f"p50={lat['p50_ms']:.3f}ms "
                          f"p95={lat['p95_ms']:.3f}ms "
                          f"p99={lat['p99_ms']:.3f}ms  "
                          f"blocks_read={rec['disk_blocks_read']:<8d} {extra}")
                # one zero-copy mmap fast-path row per workload: same
                # queries, rows gathered straight from the file mmaps
                fast = run_workload(
                    session, queries, 0, args.shards, args.warm_batches,
                    fast_path=True,
                )
                rows.append(fast)
                lat = fast["latency"]
                print(f"  {kind:<8} mmap fast-path "
                      f"{fast['queries_per_s']:>10.1f} q/s  "
                      f"{fast['rows_per_s']:>12.1f} rows/s  "
                      f"p50={lat['p50_ms']:.3f}ms "
                      f"p95={lat['p95_ms']:.3f}ms "
                      f"p99={lat['p99_ms']:.3f}ms")
                results[kind] = rows
                base = next(
                    (r for r in rows
                     if r["cache_mb"] == 0 and not r["fast_path"]), None,
                )
                cached = [r for r in rows if not r["fast_path"]]
                best = max(cached, key=lambda r: r["queries_per_s"])
                if base is not None and best is not base:
                    speedup = best["queries_per_s"] / base["queries_per_s"]
                    results[f"{kind}_speedup_vs_no_cache"] = round(speedup, 2)
                    print(f"  {kind}: warm-cache speedup vs cache-off: "
                          f"{speedup:.1f}x")
                ratio = (fast["queries_per_s"] / best["queries_per_s"]
                         if best["queries_per_s"] else 0.0)
                results[f"{kind}_fast_path_vs_best_cache"] = round(ratio, 2)
                print(f"  {kind}: mmap fast-path vs best page-cache: "
                      f"{ratio:.2f}x")
        session.close()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
