"""Serving-path benchmark: batched vertex lookups against a servable layer.

Builds an engine-shaped spill set (every vertex exactly once, scattered
across overlapping sorted files), compacts it into block-indexed servable
files, then measures the ``VertexQueryEngine`` under uniform and Zipfian
batched workloads across a sweep of page-cache budgets (0 = cache
disabled).  Reports queries/s, rows/s, cache hit rate, and disk blocks
read, as JSON with ``--json``.

Usage:
    PYTHONPATH=src python benchmarks/bench_serve.py                # 1M rows
    PYTHONPATH=src python benchmarks/bench_serve.py --vertices 200000 \
        --batches 500 --cache-mb 0,16 --json out.json              # CI scale

Acceptance target (ISSUE 2): >= 10x throughput for a Zipfian workload
with a warm cache vs cache disabled on a >= 1M-vertex store.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from repro.serve_gnn import ServableLayer, ShardedPageCache, VertexQueryEngine
from repro.serve_gnn.servable import compact_spills
from repro.storage.iostats import IOStats
from repro.storage.spill import SpillSet, write_spill


def build_servable(
    root: str,
    vertices: int,
    dim: int,
    raw_files: int,
    rows_per_file: int,
    block_rows: int,
    seed: int,
) -> tuple[list[str], dict]:
    """Write an overlapping raw spill set, then compact it — the same path
    ``GraphStore.register_servable_layer`` runs on engine output."""
    rng = np.random.default_rng(seed)
    rows = rng.standard_normal((vertices, dim)).astype(np.float32)
    perm = rng.permutation(vertices)
    raw_dir = os.path.join(root, "raw")
    os.makedirs(raw_dir, exist_ok=True)
    ss = SpillSet()
    bounds = np.linspace(0, vertices, raw_files + 1).astype(int)
    t0 = time.perf_counter()
    for i in range(raw_files):
        sel = perm[bounds[i] : bounds[i + 1]]
        ss.add(
            write_spill(
                os.path.join(raw_dir, f"raw{i:03d}.spill"),
                sel.astype(np.uint64),
                rows[sel],
            )
        )
    write_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    stats = IOStats()
    paths = compact_spills(
        ss,
        os.path.join(root, "servable"),
        rows_per_file=rows_per_file,
        block_rows=block_rows,
        stats=stats,
    )
    meta = {
        "raw_write_s": round(write_s, 2),
        "compact_s": round(time.perf_counter() - t0, 2),
        "compact_bytes_read": stats.bytes_read,
        "compact_bytes_written": stats.bytes_written,
        "servable_files": len(paths),
    }
    return paths, meta


def make_workload(
    kind: str, vertices: int, batches: int, batch: int, alpha: float, seed: int
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        return rng.integers(0, vertices, size=(batches, batch))
    if kind == "zipf":
        # rank == vertex id: ATLAS reorders hubs first, so popularity-by-id
        # is the natural serving layout
        return (rng.zipf(alpha, size=(batches, batch)) - 1) % vertices
    raise ValueError(kind)


def run_workload(
    paths: list[str],
    block_rows: int,
    queries: np.ndarray,
    cache_bytes: int,
    num_shards: int,
    warm_batches: int,
) -> dict:
    layer = ServableLayer.open(paths, block_rows=block_rows)
    cache = (
        ShardedPageCache(layer.num_blocks, cache_bytes, num_shards=num_shards)
        if cache_bytes > 0
        else None
    )
    eng = VertexQueryEngine(layer, cache=cache)
    for q in queries[:warm_batches]:
        eng.lookup(q)
    timed = queries[warm_batches:]
    t0 = time.perf_counter()
    for q in timed:
        eng.lookup(q)
    seconds = time.perf_counter() - t0
    rec = {
        "cache_mb": cache_bytes / (1 << 20),
        "batches": len(timed),
        "batch": queries.shape[1],
        "seconds": round(seconds, 4),
        "queries_per_s": round(len(timed) / seconds, 1),
        "rows_per_s": round(len(timed) * queries.shape[1] / seconds, 1),
        "disk_blocks_read": eng.blocks_read,
        "disk_bytes_read": eng.stats.bytes_read,
    }
    if cache is not None:
        rec["hit_rate"] = round(cache.hit_rate(), 4)
        rec["resident_mb"] = round(cache.resident_bytes / (1 << 20), 2)
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--vertices", type=int, default=1_000_000)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--raw-files", type=int, default=8)
    ap.add_argument("--rows-per-file", type=int, default=1 << 18)
    ap.add_argument("--block-rows", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--batches", type=int, default=2000)
    ap.add_argument("--warm-batches", type=int, default=500)
    ap.add_argument("--zipf-alpha", type=float, default=1.1)
    ap.add_argument("--cache-mb", default="0,8,64",
                    help="comma-separated page-cache budgets in MiB (0 = off)")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--workloads", default="zipf,uniform")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, help="write results to this path")
    args = ap.parse_args()

    budgets = [float(x) for x in args.cache_mb.split(",")]
    results = {
        "config": {
            k: getattr(args, k)
            for k in ("vertices", "dim", "block_rows", "batch", "batches",
                      "warm_batches", "zipf_alpha", "shards")
        }
    }
    with tempfile.TemporaryDirectory() as td:
        print(f"building servable store: V={args.vertices} d={args.dim} "
              f"({args.vertices * args.dim * 4 >> 20} MiB rows)")
        paths, meta = build_servable(
            td, args.vertices, args.dim, args.raw_files,
            args.rows_per_file, args.block_rows, args.seed,
        )
        results["build"] = meta
        print(f"  raw write {meta['raw_write_s']}s, "
              f"compaction {meta['compact_s']}s -> {meta['servable_files']} files")
        for kind in args.workloads.split(","):
            queries = make_workload(
                kind, args.vertices, args.batches + args.warm_batches,
                args.batch, args.zipf_alpha, args.seed + 1,
            )
            rows = []
            for mb in budgets:
                rec = run_workload(
                    paths, args.block_rows, queries, int(mb * (1 << 20)),
                    args.shards, args.warm_batches,
                )
                rows.append(rec)
                extra = (f"hit_rate={rec['hit_rate']}" if "hit_rate" in rec
                         else "cache off")
                print(f"  {kind:<8} cache={mb:6.1f}MiB  "
                      f"{rec['queries_per_s']:>10.1f} q/s  "
                      f"{rec['rows_per_s']:>12.1f} rows/s  "
                      f"blocks_read={rec['disk_blocks_read']:<8d} {extra}")
            results[kind] = rows
            base = next((r for r in rows if r["cache_mb"] == 0), None)
            best = max(rows, key=lambda r: r["queries_per_s"])
            if base is not None and best is not base:
                speedup = best["queries_per_s"] / base["queries_per_s"]
                results[f"{kind}_speedup_vs_no_cache"] = round(speedup, 2)
                print(f"  {kind}: warm-cache speedup vs cache-off: "
                      f"{speedup:.1f}x")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
