"""Paper §4.1 accuracy validation: OOC broadcast engine vs in-memory
dense reference.

Paper reports (Papers graph, fp32): mean-over-vertices of max-abs-err
8e-5; mean relative err 2.8e-6.  We assert the same order of magnitude
for all three GNN models on the synthetic workload.
"""

from __future__ import annotations

import tempfile

import numpy as np

from benchmarks.common import bench_graph, gnn_specs, run_atlas, save
from repro.core.atlas import AtlasConfig
from repro.models.gnn import dense_reference


def run(v=8_000, deg=10, d=64):
    rows = []
    for kind in ("gcn", "sage", "gin"):
        csr, feats = bench_graph(v=v, deg=deg, d=d, self_loops=(kind == "gcn"))
        specs = gnn_specs(kind, d)
        ref = dense_reference(csr, feats, specs)
        cfg = AtlasConfig(chunk_bytes=256 * d * 4, hot_slots=v // 6, eviction="at")
        with tempfile.TemporaryDirectory() as td:
            out, _, _ = run_atlas(td, csr, feats, specs, cfg)
        max_abs = np.abs(out - ref).max(axis=1)
        denom = np.maximum(np.abs(ref), 1e-6)
        rel = (np.abs(out - ref) / denom).mean(axis=1)
        rows.append({
            "model": kind,
            "mean_max_abs_err": float(max_abs.mean()),
            "mean_rel_err": float(rel.mean()),
        })
        print(f"[accuracy] {kind}: mean-max-abs={max_abs.mean():.2e} "
              f"mean-rel={rel.mean():.2e}  (paper: 8e-5 / 2.8e-6)")
        assert max_abs.mean() < 1e-4
    save("accuracy", rows)
    return rows


if __name__ == "__main__":
    run()
