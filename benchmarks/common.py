"""Shared helpers for the paper-figure benchmarks.

CPU-sized graphs (the paper's billion-edge runs map onto this substrate
unchanged — sizes here are chosen so the full suite runs in minutes on one
core while preserving every asymptotic the figures demonstrate)."""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.atlas import AtlasConfig, spills_to_dense
from repro.graphs.synth import (
    community_graph,
    make_features,
    powerlaw_graph,
    rmat_graph,
)
from repro.models.gnn import init_gnn_params
from repro.session import AtlasSession
from repro.storage.layout import GraphStore

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/benchmarks")

#: named graph generators the benchmark CLIs expose (--graph/--graphs);
#: all share the (num_vertices, avg_degree, seed=, self_loops=) signature
GRAPH_BUILDERS = {
    "powerlaw": powerlaw_graph,
    "community": community_graph,
    "rmat": rmat_graph,
}


def bench_graph(v=20_000, deg=12, d=64, seed=7, self_loops=True,
                graph="powerlaw"):
    csr = GRAPH_BUILDERS[graph](v, deg, seed=seed, self_loops=self_loops)
    feats = make_features(v, d, seed=seed + 1)
    return csr, feats


def run_atlas(tmpdir, csr, feats, specs, cfg: AtlasConfig,
              order="original", order_seed=0):
    """Build a store (optionally reordered at build time — csr/feats stay
    in the caller's original namespace) and run one inference pass.
    Returned dense output rows are in the store's *internal* order."""
    store = GraphStore.create(
        os.path.join(tmpdir, "store"), csr, feats,
        num_partitions=cfg.num_partitions, order=order, order_seed=order_seed,
    )
    t0 = time.perf_counter()
    session = AtlasSession(store, config=cfg, workdir=os.path.join(tmpdir, "work"))
    result = session.infer(specs)
    wall = time.perf_counter() - t0
    out = spills_to_dense(result.final.spills, csr.num_vertices, specs[-1].out_dim)
    return out, result.metrics, wall


def gnn_specs(kind: str, d_in: int, hidden=32, out=16, seed=3):
    return init_gnn_params(kind, [d_in, hidden, out], seed=seed)


def save(name: str, payload) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=2, default=float)


def fmt_bytes(n) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PiB"
