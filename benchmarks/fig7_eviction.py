"""Paper Fig 7: eviction-policy ablation (RND / LRU / AT min-pending).

AT ordering fixed; hot store small.  Paper: min-pending cuts reloads ~2x
vs RND; LRU is the WORST (recency evicts still-active high-degree hubs).
"""

from __future__ import annotations

import tempfile

from benchmarks.common import bench_graph, gnn_specs, run_atlas, save
from repro.core.atlas import AtlasConfig


def run(v=20_000, deg=12, d=64, hot_frac=10):
    # AT ordering applied at store build; inputs stay in original ids
    csr, feats = bench_graph(v=v, deg=deg, d=d)
    specs = gnn_specs("gcn", d)
    rows = []
    for policy in ("rnd", "lru", "at"):
        cfg = AtlasConfig(
            chunk_bytes=512 * d * 4, hot_slots=v // hot_frac, eviction=policy
        )
        with tempfile.TemporaryDirectory() as td:
            _, metrics, wall = run_atlas(td, csr, feats, specs, cfg, order="at")
        m0 = metrics[0]
        rows.append({
            "policy": policy, "wall_s": wall, "reloads": m0.reloads,
            "evictions": m0.evictions, "reload_pct": m0.reload_pct_mean,
            "cold_bytes": m0.cold_bytes_read + m0.cold_bytes_written,
        })
        print(f"[fig7] {policy:3s}: reloads={m0.reloads:7d} "
              f"evictions={m0.evictions:7d} reload%={m0.reload_pct_mean:5.2f} "
              f"wall={wall:.1f}s")
    save("fig7_eviction", rows)
    return rows


if __name__ == "__main__":
    run()
